"""Fault-tolerance scenario: lose devices mid-run, re-mesh, resume.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/elastic_failover.py

Phase 1 trains on a (4, 2) data×model mesh with checkpoints.  Then two
"hosts" die (we drop 4 of 8 devices).  Phase 2: ft/elastic picks the
largest surviving mesh with the same TP width (2, 2), doubles the
grad-accumulation factor so the global batch (and therefore the loss
trajectory) is preserved, restores the last checkpoint **into the new
shardings** (restore-time resharding), and continues — the loss curve
continues from where it left off.
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                # noqa: E402
import numpy as np                                        # noqa: E402

from repro.checkpoint.manager import CheckpointManager    # noqa: E402
from repro.configs import get_smoke_config                # noqa: E402
from repro.core.topology import make_plan                 # noqa: E402
from repro.data.pipeline import DataConfig, synthetic_batch  # noqa: E402
from repro.ft.elastic import make_elastic_mesh, plan_remesh  # noqa: E402
from repro.optim.schedules import make_schedule           # noqa: E402
from repro.runtime import Runtime                         # noqa: E402

CKPT = "/tmp/elastic_demo_ckpt"
GLOBAL_BATCH, SEQ = 16, 64


def run_phase(mesh, cfg, dcfg, *, steps, start, microbatches, restore):
    rt = Runtime.create(cfg, mesh, shape_kind="train", seq_len=SEQ,
                        grad_sync="hierarchical")
    shardings = rt.state_shardings
    jstep = rt.compile_train_step(
        microbatches=microbatches,
        schedule=make_schedule("constant", peak=3e-3), donate=False)
    mgr = CheckpointManager(CKPT, save_every=5, async_save=False)
    with mesh:
        if restore:
            state, at = mgr.restore_latest(rt.init_train_state(),
                                           shardings=shardings)
            assert state is not None
            print(f"  restored step {at} into mesh "
                  f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
            start = at + 1
        else:
            state = jax.device_put(rt.init_train_state(), shardings)
        bspec = rt.batch_sharding
        losses = []
        for s in range(start, start + steps):
            batch = {k: jax.device_put(v, bspec)
                     for k, v in synthetic_batch(dcfg, s).items()}
            state, metrics = jstep(state, batch)
            mgr.maybe_save(s, state)
            losses.append(float(metrics["loss"]))
        mgr.maybe_save(start + steps - 1, state, force=True)
        mgr.wait()
    return losses, start + steps - 1


def main():
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_smoke_config("exanode-100m")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=SEQ,
                      global_batch=GLOBAL_BATCH, branch=4)

    print("phase 1: healthy mesh (4 data x 2 model), 15 steps")
    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    losses1, last = run_phase(mesh1, cfg, dcfg, steps=15, start=0,
                              microbatches=1, restore=False)
    print(f"  loss {losses1[0]:.3f} -> {losses1[-1]:.3f}")

    print("FAILURE: 4 of 8 devices lost (one 'MCM' down)")
    plan1 = make_plan(cfg, {"data": 4, "model": 2})
    decision = plan_remesh(cfg, old_plan=plan1, n_surviving=4,
                           global_batch=GLOBAL_BATCH, seq_len=SEQ,
                           old_microbatches=1)
    print(f"  remesh decision: shape={decision.mesh_shape} "
          f"microbatches={decision.microbatches} ({decision.note})")

    print("phase 2: resume on the surviving mesh")
    mesh2 = make_elastic_mesh(decision, devices=jax.devices()[:4])
    losses2, _ = run_phase(mesh2, cfg, dcfg,
                           steps=10, start=last + 1,
                           microbatches=decision.microbatches, restore=True)
    print(f"  loss {losses2[0]:.3f} -> {losses2[-1]:.3f}")

    # the resumed trajectory must continue, not restart
    assert losses2[0] < losses1[0], (losses1[0], losses2[0])
    print("elastic_failover OK")


if __name__ == "__main__":
    main()
