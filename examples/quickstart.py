"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced gemma-2b, plans its distribution for the current devices,
runs a few train steps on synthetic data, then serves a greedy completion
— the whole stack end to end on one CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.topology import describe, make_plan
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.api import model_specs
from repro.models.common import count_params, init_params
from repro.optim.schedules import make_schedule
from repro.serve.engine import Request, ServeEngine
from repro.train.state import init_train_state
from repro.train.steps import make_train_step

# 1. pick an architecture (any of the 10 assigned ones + the demo config)
cfg = get_smoke_config("gemma-2b")
specs = model_specs(cfg)
print(f"arch={cfg.name}  params={count_params(specs):,}")

# 2. plan the distribution for whatever devices exist (1 CPU here; the
#    same call plans the 2x16x16 production mesh in launch/)
plan = make_plan(cfg, {}, shape_kind="train", seq_len=64)
print(describe(plan))

# 3. train a few steps on the deterministic synthetic bigram stream
step = jax.jit(make_train_step(cfg, plan, specs, None,
                               schedule=make_schedule("constant", peak=3e-3)))
state = init_train_state(specs, jax.random.PRNGKey(0), plan)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                  branch=4)
for i in range(10):
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, i).items()}
    state, metrics = step(state, batch)
    if i % 3 == 0:
        print(f"step {i}: loss={float(metrics['loss']):.4f}")

# 4. serve greedy completions from the trained weights
eng = ServeEngine(cfg, plan, None, state.params, num_slots=2, capacity=48)
rng = np.random.default_rng(0)
for rid in range(3):
    eng.submit(Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab_size, size=8,
                                           dtype=np.int32),
                       max_new_tokens=8))
stats = eng.run_to_completion()
print("serve:", stats.summary)
for r in eng.finished:
    print(f"  request {r.rid}: generated {r.generated}")
print("quickstart OK")
