"""Quickstart: the public `repro.runtime` API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

One ``Runtime.create`` call owns the whole chain — arch registry lookup,
fabric-aware Plan, parameter specs, compiled executables.  Builds a reduced
gemma-2b, trains a few steps on synthetic data, then serves greedy
completions from the trained weights — the whole stack end to end on one
CPU (the same calls plan the 2x16x16 production mesh in launch/).
"""
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_batch
from repro.optim.schedules import make_schedule
from repro.runtime import Runtime
from repro.serve.engine import Request

# 1. build the runtime: arch registry -> fabric plan -> specs -> executables
rt = Runtime.create("gemma-2b", smoke=True, shape_kind="train", seq_len=64)
print(rt.describe())

# 2. train a few steps on the deterministic synthetic bigram stream
jstep = rt.compile_train_step(
    schedule=make_schedule("constant", peak=3e-3))
state = rt.init_train_state()
dcfg = DataConfig(vocab_size=rt.cfg.vocab_size, seq_len=64, global_batch=8,
                  branch=4)
for i in range(10):
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, i).items()}
    state, metrics = jstep(state, batch)
    if i % 3 == 0:
        print(f"step {i}: loss={float(metrics['loss']):.4f}")

# 3. re-plan the same runtime for decode and serve greedy completions from
#    the trained weights (continuous batching, donated in-place KV caches)
srv = rt.reshape(shape_kind="decode", capacity=48)
eng = srv.engine(num_slots=2, params=state.params)
rng = np.random.default_rng(0)
for rid in range(3):
    eng.submit(Request(rid=rid,
                       prompt=rng.integers(0, rt.cfg.vocab_size, size=8,
                                           dtype=np.int32),
                       max_new_tokens=8))
stats = eng.run_to_completion()
print("serve:", stats.summary)
for r in eng.finished:
    print(f"  request {r.rid}: generated {r.generated}")
print("quickstart OK")
